"""Unit tests for the perf-trajectory table loader in benchmarks/make_tables.

Covers the BENCH filename grammar (``BENCH_<rev>[_<mode>].json``), per-rev
dedupe (newest timestamp wins), graceful handling of unknown / corrupt /
foreign files, and hash-prefix rev ordering against git history.
"""
from __future__ import annotations

import importlib.util
import json
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_spec = importlib.util.spec_from_file_location(
    "make_tables", os.path.join(_ROOT, "benchmarks", "make_tables.py"))
mt = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(mt)


def _write(d, name, **payload):
    payload.setdefault("rows", [])
    with open(os.path.join(d, name), "w") as f:
        json.dump(payload, f)


@pytest.fixture()
def bench_dir(tmp_path, monkeypatch):
    # pin history so ordering is deterministic and independent of the repo
    monkeypatch.setattr(mt, "_git_rev_order",
                        lambda: {"aaa1111": 0, "bbb2222": 1, "ccc3333": 2})
    return str(tmp_path)


def test_filename_grammar(bench_dir):
    _write(bench_dir, "BENCH_aaa1111_smoke.json", rev="aaa1111", timestamp="t1")
    _write(bench_dir, "BENCH_bbb2222.json", rev="bbb2222", timestamp="t2")
    _write(bench_dir, "BENCH_ccc3333_quick.json", rev="ccc3333", timestamp="t3")
    # rev containing an underscore still parses (mode matched from known set)
    _write(bench_dir, "BENCH_no_rev_smoke.json", timestamp="t0")
    assert [d["rev"] for d in mt.load_trajectory("smoke", bench_dir)] == \
        ["aaa1111", "no_rev"]
    assert [d["rev"] for d in mt.load_trajectory("full", bench_dir)] == \
        ["bbb2222"]
    assert [d["rev"] for d in mt.load_trajectory("quick", bench_dir)] == \
        ["ccc3333"]


def test_per_rev_dedupe_newest_timestamp_wins(bench_dir):
    # two files claim rev aaa1111 for the same mode (embedded rev overrides
    # the filename): only the newer timestamp survives
    _write(bench_dir, "BENCH_aaa1111_smoke.json", rev="aaa1111",
           timestamp="2026-01-01T00:00:00", marker="old")
    _write(bench_dir, "BENCH_zzz9999_smoke.json", rev="aaa1111",
           timestamp="2026-02-01T00:00:00", marker="new")
    runs = mt.load_trajectory("smoke", bench_dir)
    assert len(runs) == 1
    assert runs[0]["marker"] == "new"


def test_mode_isolation(bench_dir):
    # a smoke file never leaks into the full/quick tables and vice versa
    _write(bench_dir, "BENCH_aaa1111_smoke.json", rev="aaa1111", timestamp="t")
    _write(bench_dir, "BENCH_aaa1111_quick.json", rev="aaa1111", timestamp="t")
    _write(bench_dir, "BENCH_aaa1111.json", rev="aaa1111", timestamp="t")
    for mode in ("smoke", "quick", "full"):
        assert len(mt.load_trajectory(mode, bench_dir)) == 1


def test_unknown_revs_sort_after_history(bench_dir):
    _write(bench_dir, "BENCH_bbb2222_smoke.json", rev="bbb2222",
           timestamp="t5")
    _write(bench_dir, "BENCH_feature1_smoke.json", rev="feature1",
           timestamp="t1")
    _write(bench_dir, "BENCH_feature2_smoke.json", rev="feature2",
           timestamp="t2")
    revs = [d["rev"] for d in mt.load_trajectory("smoke", bench_dir)]
    # known rev first, then unknowns in timestamp order — no KeyError
    assert revs == ["bbb2222", "feature1", "feature2"]


def test_hash_prefix_matching(bench_dir):
    # the bench writer abbreviated longer than `git log --format=%h` did
    _write(bench_dir, "BENCH_bbb2222abcd_smoke.json", rev="bbb2222abcd",
           timestamp="t1")
    _write(bench_dir, "BENCH_aaa1_smoke.json", rev="aaa1", timestamp="t2")
    revs = [d["rev"] for d in mt.load_trajectory("smoke", bench_dir)]
    # aaa1 is a prefix of aaa1111 (pos 0); bbb2222abcd extends bbb2222 (pos 1)
    assert revs == ["aaa1", "bbb2222abcd"]
    assert mt._rev_position("aaa1", {"aaa1111": 0}) == 0
    assert mt._rev_position("aaa1111ff", {"aaa1111": 0}) == 0
    assert mt._rev_position("dddd", {"aaa1111": 0}) == 1


def test_corrupt_and_foreign_files_skipped(bench_dir):
    _write(bench_dir, "BENCH_aaa1111_smoke.json", rev="aaa1111", timestamp="t")
    with open(os.path.join(bench_dir, "BENCH_bbb2222_smoke.json"), "w") as f:
        f.write("{truncated")
    _write(bench_dir, "baseline.json", rev="x")     # not a BENCH file
    _write(bench_dir, "BENCHMARK_note.txt.json", rev="x")  # wrong prefix
    runs = mt.load_trajectory("smoke", bench_dir)
    assert [d["rev"] for d in runs] == ["aaa1111"]


def test_trajectory_table_renders_deduped_runs(bench_dir):
    _write(bench_dir, "BENCH_aaa1111_smoke.json", rev="aaa1111",
           timestamp="t1", rows=[{"name": "fit", "us_per_call": 12.5,
                                  "mpts_per_s": 3.0, "roofline_frac": 0.5}])
    _write(bench_dir, "BENCH_bbb2222_smoke.json", rev="bbb2222",
           timestamp="t2", rows=[{"name": "fit", "us_per_call": 10.0,
                                  "mpts_per_s": 4.0, "roofline_frac": 0.6}])
    table = mt.trajectory_table(mt.load_trajectory("smoke", bench_dir))
    assert "| fit | 12.5 | 10.0 |" in table
    assert "4.00" in table and "60.00%" in table
