"""repro.api: one declarative FitSpec, four execution surfaces.

The heart is the cross-surface parity matrix: the SAME FitSpec must
produce coefficient-identical results (κ-scaled tolerance) on eager vs
streaming vs distributed vs serve, over method × basis × degree-search
cells — including the cells the spec redesign newly unlocked
(IRLS+streaming, DegreeSearch+IRLS).  Distributed cells run when the
process was started with XLA_FLAGS=--xla_force_host_platform_device_count=8
(same convention as tests/test_distributed_fit.py) and are skipped
otherwise; the other three surfaces always run.

Also here: FitSpec construction-time validation, the spec-keyed compile
cache (equal specs share one executable), the polyfit_qr deprecation
(matching the use_kernel= precedent), per-request serve solver policy,
and the public-API snapshot (core.__all__ + api.__all__ frozen to a
checked-in list so accidental surface growth fails CI).
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, core
from repro.core import streaming

HAVE_DEVICES = len(jax.devices()) >= 8

rng = np.random.default_rng(7)
N = 1024
_x = rng.uniform(-2.0, 2.0, N)
TRUE = np.array([1.0, -0.5, 0.0, 0.3])
_clean = np.polyval(TRUE[::-1], _x)
X = jnp.asarray(_x, jnp.float32)
Y_EXACT = jnp.asarray(_clean, jnp.float32)
Y_NOISY = jnp.asarray(_clean + rng.normal(0, 0.05, N), jnp.float32)


# --------------------------------------------------------------------------
# FitSpec: construction-time validation, hashability, compile-cache keying
# --------------------------------------------------------------------------
class TestFitSpec:
    def test_defaults_validate(self):
        spec = api.FitSpec()
        assert spec.degree == 3 and spec.method == "lse"
        assert hash(spec) == hash(api.FitSpec())
        assert spec == api.FitSpec()

    @pytest.mark.parametrize("make", [
        lambda: api.FitSpec(method="nope"),
        lambda: api.FitSpec(basis="legendre"),
        lambda: api.FitSpec(engine="cuda"),
        lambda: api.FitSpec(degree=-1),
        lambda: api.FitSpec(decay=0.0),
        lambda: api.FitSpec(decay=1.5),
        lambda: api.FitSpec(ridge=-1e-3),
        lambda: api.FitSpec(method="lspia", degree=api.DegreeSearch()),
        lambda: api.FitSpec(numerics=api.NumericsPolicy(solver="lspia")),
        lambda: api.FitSpec(numerics=api.NumericsPolicy(
            solver="qr_vandermonde"), method="irls"),
        lambda: api.FitSpec(numerics=api.NumericsPolicy(
            solver="qr_vandermonde"), degree=api.DegreeSearch()),
        lambda: api.FitSpec(engine="kernel", basis="chebyshev"),
        lambda: api.IRLSOptions(loss="cauchy"),
    ])
    def test_invalid_specs_raise_at_construction(self, make):
        with pytest.raises(ValueError):
            make()

    def test_domain_accepts_domain_and_tuple(self):
        from repro.core import basis as basis_lib
        a = api.FitSpec(domain=(1.0, 0.5))
        b = api.FitSpec(domain=basis_lib.Domain(jnp.float32(1.0),
                                                jnp.float32(0.5)))
        assert a == b and a.domain == (1.0, 0.5)

    def test_equal_specs_share_one_executable(self):
        """The compile cache keys on spec identity: re-running an equal
        spec adds no executable; a different spec adds exactly one."""
        from repro.api import executors
        x = jnp.linspace(-1, 1, 64)
        y = x * 2.0 + 1.0
        api.fit(x, y, api.FitSpec(degree=2))
        base = executors._fit_lse_fixed._cache_size()
        api.fit(x, y, api.FitSpec(degree=2))        # equal spec, new object
        assert executors._fit_lse_fixed._cache_size() == base
        api.fit(x, y, api.FitSpec(degree=2, ridge=1e-6))
        assert executors._fit_lse_fixed._cache_size() == base + 1

    def test_shim_polyfit_identical_to_spec_fit(self):
        a = core.polyfit(X, Y_NOISY, 3)
        b = api.fit(X, Y_NOISY, api.FitSpec(degree=3))
        np.testing.assert_array_equal(np.asarray(a.coeffs),
                                      np.asarray(b.coeffs))
        assert b.report is not None and np.isfinite(float(b.report.sse))


# --------------------------------------------------------------------------
# polyfit_qr fold-in + deprecation (matching the use_kernel= precedent)
# --------------------------------------------------------------------------
class TestQRVandermonde:
    def test_polyfit_qr_warns_and_matches_spec_path(self):
        with pytest.warns(DeprecationWarning, match="qr_vandermonde"):
            old = core.polyfit_qr(X, Y_NOISY, 3)
        new = api.fit(X, Y_NOISY, api.FitSpec(
            method="lse",
            numerics=api.NumericsPolicy(solver="qr_vandermonde",
                                        fallback=None)))
        np.testing.assert_array_equal(np.asarray(old.coeffs),
                                      np.asarray(new.coeffs))
        via_polyfit = core.polyfit(X, Y_NOISY, 3, solver="qr_vandermonde")
        np.testing.assert_array_equal(np.asarray(old.coeffs),
                                      np.asarray(via_polyfit.coeffs))

    def test_qr_vandermonde_close_to_normal_equations(self):
        qr = core.polyfit(X, Y_NOISY, 3, solver="qr_vandermonde")
        ge = core.polyfit(X, Y_NOISY, 3)
        np.testing.assert_allclose(np.asarray(qr.coeffs),
                                   np.asarray(ge.coeffs),
                                   rtol=1e-3, atol=1e-3)

    def test_raw_data_solver_rejected_on_moment_surfaces(self):
        spec = api.FitSpec(numerics=api.NumericsPolicy(
            solver="qr_vandermonde"))
        with pytest.raises(ValueError, match="moments|Vandermonde"):
            spec.streaming()
        from repro.serve import FitServeConfig, FitServeEngine
        eng = FitServeEngine(FitServeConfig(n_slots=1, buckets=(32,)))
        with pytest.raises(ValueError, match="moments|Vandermonde"):
            eng.submit(np.ones(8), np.ones(8), spec=spec)


# --------------------------------------------------------------------------
# the cross-surface parity matrix
# --------------------------------------------------------------------------
def _eager(spec, x, y):
    return api.fit(x, y, spec)


def _stream(spec, x, y, chunks=4):
    st = spec.streaming()
    n = x.shape[-1] // chunks
    for i in range(chunks):
        st = streaming.update(st, x[i * n:(i + 1) * n],
                              y[i * n:(i + 1) * n])
    return api.stream_result(st)


def _serve(spec, x, y):
    from repro.serve import FitServeConfig, FitServeEngine
    eng = FitServeEngine(FitServeConfig(spec=spec, n_slots=2,
                                        buckets=(256,)))
    req = eng.submit(np.asarray(x), np.asarray(y), spec=spec)
    eng.run()
    assert req.done
    return req


def _distributed(spec, x, y):
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_host_mesh(data=8, model=1)
    return spec.distributed(mesh)(x, y)


def _coeff_tol(res, scale=200.0):
    """κ-scaled absolute tolerance: the honest fp-difference budget for
    two evaluations of the same solve from differently-ordered f32 sums."""
    kappa = 1.0
    diag = res.poly.diagnostics
    if diag is not None:
        k = float(np.max(np.asarray(diag.condition)))
        if np.isfinite(k):
            kappa = max(kappa, k)
    cscale = max(1.0, float(np.max(np.abs(np.asarray(res.coeffs)))))
    return scale * kappa * np.finfo(np.float32).eps * cscale


# (name, spec, y, extra absolute slack for iterative/approximate surfaces)
MATRIX_CELLS = [
    ("lse-monomial-d3",
     api.FitSpec(degree=3), Y_NOISY, 0.0),
    ("lse-chebyshev-d4-pinned",
     api.FitSpec(degree=4, basis="chebyshev", domain=(0.0, 0.5)),
     Y_NOISY, 0.0),
    ("lse-decayless-ridge",
     api.FitSpec(degree=2, ridge=1e-6), Y_NOISY, 0.0),
    ("irls-huber-d3",
     api.FitSpec(degree=3, method="irls"), Y_EXACT, 1e-4),
    ("irls-tukey-cheb-d3",
     api.FitSpec(degree=3, basis="chebyshev", domain=(0.0, 0.5),
                 method="irls", irls=api.IRLSOptions(loss="tukey")),
     Y_EXACT, 1e-4),
    ("lspia-d3-pinned",
     api.FitSpec(degree=3, method="lspia", domain=(0.0, 0.5)),
     Y_NOISY, 5e-3),
    ("search-aicc-lse",
     api.FitSpec(degree=api.DegreeSearch(max_degree=5, folds=0,
                                         criterion="aicc")), Y_NOISY, 0.0),
    # noisy (not exact) data: on an exact interpolation every rung past
    # the true degree has SSE at roundoff and the criteria order noise;
    # with real noise the BIC gaps dwarf the small difference between the
    # surfaces' IRLS weights (converged loop vs per-chunk reweighting)
    ("search-bic-irls",
     api.FitSpec(degree=api.DegreeSearch(max_degree=4, folds=0,
                                         criterion="bic"),
                 method="irls"), Y_NOISY, 5e-3),
]


def _result_coeffs(out):
    """Uniform (degree, coeffs) view across surface result types."""
    if isinstance(out, api.FitResult):
        if out.selection is not None:
            d = int(np.asarray(out.selection.best_degree))
            c = np.asarray(out.coeffs)[..., :d + 1]
            return d, c
        c = np.asarray(out.coeffs)
        return c.shape[-1] - 1, c
    # a served FitRequest
    return int(out.degree), np.asarray(out.coeffs)


@pytest.mark.parametrize("name,spec,y,slack",
                         MATRIX_CELLS, ids=[c[0] for c in MATRIX_CELLS])
def test_parity_matrix(name, spec, y, slack):
    """One FitSpec, every surface, coefficient-identical answers."""
    ref = _eager(spec, X, y)
    d_ref, c_ref = _result_coeffs(ref)
    tol = _coeff_tol(ref) + slack
    surfaces = {"streaming": _stream, "serve": _serve}
    if HAVE_DEVICES:
        surfaces["distributed"] = _distributed
    for sname, run in surfaces.items():
        out = run(spec, X, y)
        d, c = _result_coeffs(out)
        assert d == d_ref, (f"{name}/{sname}: degree {d} != eager {d_ref}")
        np.testing.assert_allclose(
            c[..., :d_ref + 1], c_ref, atol=tol, rtol=tol,
            err_msg=f"{name}/{sname} diverged from eager (tol={tol:.2e})")


def test_matrix_covers_every_capability_axis():
    """The acceptance grid: every method, both bases, fixed + search —
    expressible as a FitSpec and present in the parity matrix."""
    specs = [c[1] for c in MATRIX_CELLS]
    assert {s.method for s in specs} == {"lse", "irls", "lspia"}
    assert {s.basis for s in specs} == {"monomial", "chebyshev"}
    assert any(s.is_search for s in specs)
    assert any(not s.is_search for s in specs)
    assert any(s.ridge > 0 for s in specs)
    assert any(s.domain is not None for s in specs)


# --------------------------------------------------------------------------
# newly unlocked cells, behaviorally
# --------------------------------------------------------------------------
class TestUnlockedCells:
    def test_streaming_irls_downweights_outliers(self):
        """IRLS over streams: the spec-carrying state reweights each chunk
        against the running fit, so gross outliers in later chunks barely
        move the coefficients — while the plain LSE stream is dragged."""
        rng = np.random.default_rng(3)
        xs = rng.uniform(-2, 2, 2048).astype(np.float32)
        ys = np.polyval(TRUE[::-1], xs).astype(np.float32)
        ys_bad = ys.copy()
        bad = rng.choice(np.arange(1024, 2048), 200, replace=False)
        ys_bad[bad] += rng.choice([-1.0, 1.0], 200) * 50.0

        def run(spec):
            st = spec.streaming()
            for lo in range(0, 2048, 256):
                st = streaming.update(st, jnp.asarray(xs[lo:lo + 256]),
                                      jnp.asarray(ys_bad[lo:lo + 256]))
            return np.asarray(api.stream_result(st).coeffs)

        robust = run(api.FitSpec(degree=3, method="irls",
                                 irls=api.IRLSOptions(loss="tukey")))
        plain = run(api.FitSpec(degree=3))
        err_r = np.linalg.norm(robust - TRUE) / np.linalg.norm(TRUE)
        err_p = np.linalg.norm(plain - TRUE) / np.linalg.norm(TRUE)
        assert err_r < 0.05, f"streaming IRLS err {err_r:.3f}"
        assert err_r < err_p / 5, (err_r, err_p)

    def test_degree_search_under_robust_loss(self):
        """DegreeSearch+IRLS: contamination that breaks plain selection is
        survived when the ladder rides on IRLS weights."""
        rng = np.random.default_rng(4)
        xs = rng.uniform(-2, 2, 4096)
        sig = np.polyval(TRUE[::-1], xs)
        ys = sig + 0.05 * rng.normal(0, 1, 4096)
        bad = rng.choice(4096, 800, replace=False)
        ys[bad] += rng.choice([-1.0, 1.0], 800) * 50.0
        x = jnp.asarray(xs, jnp.float32)
        y = jnp.asarray(ys, jnp.float32)
        res = api.fit(x, y, api.FitSpec(
            degree=api.DegreeSearch(max_degree=6, folds=5),
            method="irls", irls=api.IRLSOptions(loss="tukey")))
        assert int(np.asarray(res.selection.best_degree)) == 3
        # max_degree=6 auto-normalizes the domain — convert back to raw-x
        raw = np.asarray(res.selection.poly.monomial_coeffs(), np.float64)
        err = np.linalg.norm(raw - TRUE) / np.linalg.norm(TRUE)
        assert err < 0.05, f"robust-search coeff err {err:.3f}"
        # the same contamination sinks the plain (LSE) search entirely
        plain = api.fit(x, y, api.FitSpec(
            degree=api.DegreeSearch(max_degree=6, folds=5)))
        assert int(np.asarray(plain.selection.best_degree)) != 3

    def test_streaming_search_with_cv_folds(self):
        """DegreeSearch folds become chunk-round-robin CV partials."""
        spec = api.FitSpec(degree=api.DegreeSearch(max_degree=5, folds=5))
        st = spec.streaming()
        assert st.fold_moments is not None
        for i in range(10):
            lo = i * 100
            st = streaming.update(st, X[lo:lo + 100], Y_NOISY[lo:lo + 100])
        out = api.stream_result(st)
        assert out.selection.criterion == "cv"
        assert int(np.asarray(out.selection.best_degree)) == 3

    def test_eager_decay_equals_streaming_decay(self):
        """spec.decay on the eager surface == the chunked stream == the
        mesh (each shard reconstructs its global γ ages) — the γ-weighted
        LSE identity, now reachable from one spec on every surface."""
        spec = api.FitSpec(degree=2, decay=0.999)
        res = _eager(spec, X[:512], Y_NOISY[:512])
        st = spec.streaming()
        for lo in range(0, 512, 128):
            st = streaming.update(st, X[lo:lo + 128],
                                  Y_NOISY[lo:lo + 128])
        out = api.stream_result(st)
        np.testing.assert_allclose(np.asarray(out.coeffs),
                                   np.asarray(res.coeffs),
                                   rtol=1e-3, atol=1e-3)
        if HAVE_DEVICES:
            dist = _distributed(spec, X[:512], Y_NOISY[:512])
            np.testing.assert_allclose(np.asarray(dist.coeffs),
                                       np.asarray(res.coeffs),
                                       rtol=1e-3, atol=1e-3)

    def test_ridge_search_spec_honored_on_every_surface(self):
        """A ridge-stabilized DegreeSearch solves the ladder on the λI
        state but scores raw, identically on eager/streaming/serve (the
        divergence the spec layer exists to prevent)."""
        spec = api.FitSpec(degree=api.DegreeSearch(max_degree=4, folds=0,
                                                   criterion="aicc"),
                           ridge=1e-4)
        ref = _eager(spec, X, Y_NOISY)
        d_ref, c_ref = _result_coeffs(ref)
        assert d_ref == 3
        for run in (_stream, _serve):
            d, c = _result_coeffs(run(spec, X, Y_NOISY))
            assert d == d_ref
            np.testing.assert_allclose(c[..., :d_ref + 1], c_ref,
                                       rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# serve: per-request solver policy (the FitServeConfig satellite)
# --------------------------------------------------------------------------
class TestServePerRequestPolicy:
    def test_cond_cap_specs_coexist_without_recompiles(self):
        """Two specs that differ only in cond_cap each compile their solve
        ONCE (spec-keyed static arg), then arbitrary traffic of both mixes
        with zero further recompiles — and the tighter cap demonstrably
        flips the fallback on the same data."""
        from repro.serve import FitServeConfig, FitServeEngine
        eng = FitServeEngine(FitServeConfig(degree=3, n_slots=2,
                                            buckets=(128,), ridge=1e-9))
        warm = eng.warmup()
        x = np.asarray(X[:400])
        y = np.asarray(Y_NOISY[:400])
        tight = api.FitSpec(degree=3, numerics=api.NumericsPolicy(
            solver="gauss", fallback="svd", cond_cap=1.0))
        loose = api.FitSpec(degree=3, numerics=api.NumericsPolicy(
            solver="gauss", fallback="svd"))
        a = eng.submit(x, y, spec=tight)
        b = eng.submit(x, y, spec=loose)
        eng.run()
        after_first_use = eng.compiled_executables()
        assert after_first_use == warm + 2   # one compile per novel spec
        reqs = [eng.submit(x, y, spec=s)
                for s in (tight, loose) * 4]
        eng.run()
        assert eng.compiled_executables() == after_first_use
        assert all(r.done for r in reqs)
        assert a.fallback_used and not b.fallback_used
        np.testing.assert_allclose(a.coeffs, b.coeffs, rtol=5e-2, atol=5e-2)

    def test_nested_degree_served_from_truncated_state(self):
        from repro.serve import FitServeConfig, FitServeEngine
        eng = FitServeEngine(FitServeConfig(degree=3, n_slots=2,
                                            buckets=(128,)))
        x, y = np.asarray(X[:300]), np.asarray(Y_NOISY[:300])
        req = eng.submit(x, y, spec=api.FitSpec(degree=1))
        eng.run()
        ref = core.polyfit(jnp.asarray(x), jnp.asarray(y), 1)
        assert req.degree == 1 and req.coeffs.shape == (2,)
        np.testing.assert_allclose(req.coeffs, np.asarray(ref.coeffs),
                                   rtol=5e-3, atol=5e-3)

    def test_pool_mismatched_specs_rejected(self):
        from repro.serve import FitServeConfig, FitServeEngine
        eng = FitServeEngine(FitServeConfig(degree=3, n_slots=1,
                                            buckets=(64,)))
        x, y = np.ones(8, np.float32), np.ones(8, np.float32)
        with pytest.raises(ValueError, match="basis"):
            eng.submit(x, y, spec=api.FitSpec(degree=2, basis="chebyshev"))
        with pytest.raises(ValueError, match="domain"):
            eng.submit(x, y, spec=api.FitSpec(degree=2, domain=(0.0, 1.0)))
        with pytest.raises(ValueError, match="decay"):
            eng.submit(x, y, spec=api.FitSpec(degree=2, decay=0.99))
        with pytest.raises(ValueError, match="exceeds"):
            eng.submit(x, y, spec=api.FitSpec(degree=5))
        with pytest.raises(ValueError, match="criterion"):
            eng.submit(x, y, spec=api.FitSpec(
                degree=api.DegreeSearch(max_degree=3, criterion="cv")))
        with pytest.raises(ValueError, match="degree= or spec="):
            eng.submit(x, y, degree=3, spec=api.FitSpec(degree=3))

    def test_legacy_submit_spellings_still_pinned(self):
        from repro.serve import FitServeConfig, FitServeEngine
        eng = FitServeEngine(FitServeConfig(n_slots=1, buckets=(32,)))
        with pytest.raises(ValueError):
            eng.submit(np.ones(3), np.ones(4))
        with pytest.raises(ValueError, match="determine"):
            eng.submit(np.ones(2), np.ones(2))
        with pytest.raises(ValueError):
            eng.submit(np.ones(8), np.ones(8), degree=2)


# --------------------------------------------------------------------------
# the public-API snapshot: surface growth must be deliberate
# --------------------------------------------------------------------------
SNAPSHOT = os.path.join(os.path.dirname(__file__),
                        "public_api_snapshot.txt")


def test_public_api_snapshot():
    """repro.core.__all__ + repro.api.__all__ frozen to the checked-in
    list: adding (or dropping) a public name without updating
    tests/public_api_snapshot.txt fails CI."""
    with open(SNAPSHOT) as f:
        frozen = {ln.strip() for ln in f
                  if ln.strip() and not ln.startswith("#")}
    live = ({f"core.{n}" for n in core.__all__}
            | {f"api.{n}" for n in api.__all__})
    added = sorted(live - frozen)
    removed = sorted(frozen - live)
    assert not added and not removed, (
        f"public API drifted: added={added} removed={removed}; if "
        "deliberate, update tests/public_api_snapshot.txt")


def test_all_names_resolve():
    for name in core.__all__:
        assert getattr(core, name) is not None
    for name in api.__all__:
        assert getattr(api, name) is not None


# --------------------------------------------------------------------------
# use_kernel precedent intact through the shim layer
# --------------------------------------------------------------------------
def test_use_kernel_deprecation_survives_shim():
    x = jnp.linspace(-1, 1, 256)
    y = 1.0 + 2.0 * x
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        a = core.polyfit(x, y, 1, use_kernel=False).coeffs
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        b = core.polyfit(x, y, 1, engine="reference").coeffs
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
