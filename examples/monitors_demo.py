"""The paper's technique as framework telemetry: loss-curve fitting,
divergence detection, ETA, straggler detection, scaling-law fits.

    PYTHONPATH=src python examples/monitors_demo.py
"""
import sys
sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro import core
from repro.runtime import plan_reslice
from repro.train import LossCurveMonitor, StepTimeMonitor

print("=== Loss-curve monitor (streaming matricized LSE) ===")
mon = LossCurveMonitor(degree=1, decay=0.995)
rng = np.random.default_rng(0)
for step in range(300):
    loss = 6.0 * (step + 10) ** -0.15 + rng.normal(0, 0.02)
    mon.observe(step, loss)
print(f"fitted slope @300: {mon.slope_at(300):+.2e} /step")
print(f"predicted loss @600: {mon.predict(600):.3f}")
print(f"eta to loss 4.0: {mon.eta_to(4.0, 300)} steps")
print(f"diverging? {mon.diverging(300)}")

print("\n=== Straggler detection + work re-slicing ===")
st = StepTimeMonitor(n_hosts=8, threshold=1.3)
for step in range(25):
    t = 1.0 + rng.normal(0, 0.02, 8)
    t[3] = 1.6 + rng.normal(0, 0.05)        # host 3 is slow
    st.observe(step, t)
print("stragglers:", st.stragglers(25))
plan = plan_reslice(st, 25, global_batch=256)
print("re-sliced per-host batch shares:", plan.shares)

print("\n=== Scaling-law fit (log-log matricized LSE) ===")
tokens = jnp.asarray(np.logspace(7, 10, 40), jnp.float32)
loss = 2.57e3 * tokens ** -0.35 + 1.69     # chinchilla-ish synthetic
law = core.fit_power_law(tokens, loss)
print(f"fit: loss = {float(law.scale):.3g} · D^{float(law.exponent):.3f} "
      f"+ {float(law.offset):.2f}")
print(f"predicted loss at 1e11 tokens: {float(law(jnp.asarray(1e11))):.3f}")
