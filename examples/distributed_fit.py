"""Distributed matricized LSE on a (simulated) multi-device mesh — the
paper's parallelization at mesh scale with one O(m²) collective.

    PYTHONPATH=src python examples/distributed_fit.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp

from repro import core
from repro.data import curve_dataset
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roof

mesh = mesh_lib.make_host_mesh(data=8, model=1)
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

n = 1 << 22  # 4M points, sharded 8 ways
x, y, true = curve_dataset(n, degree=3, noise=2.0, seed=1)

fit = core.make_distributed_fit(mesh, degree=3, normalize=True,
                                accum_dtype=jnp.float32)
poly, moments = fit(x, y)
print("true coeffs      :", true)
print("distributed fit  :", poly.monomial_coeffs())
print("points seen      :", int(moments.count))

# The paper's systems claim, verified on the compiled HLO: the only
# cross-device traffic is the O(m²) moment psum — independent of n.
s = jax.ShapeDtypeStruct((n,), jnp.float32)
coll = roof.collective_bytes(fit.lower(s, s, s).compile().as_text())
print(f"collective wire bytes for {n:,} points: {sum(coll.values()):.0f}B "
      f"({coll})")

# weak scaling: double the data, same collective payload
s2 = jax.ShapeDtypeStruct((2 * n,), jnp.float32)
coll2 = roof.collective_bytes(fit.lower(s2, s2, s2).compile().as_text())
print(f"collective wire bytes for {2 * n:,} points: "
      f"{sum(coll2.values()):.0f}B (payload is n-independent)")
