"""End-to-end driver: train a ~100M-param llama-style LM for a few hundred
steps on synthetic data, with the paper's LSE loss-curve monitor, periodic
checkpointing and crash-resume.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(CPU: ~1-2 s/step at batch 8 × seq 256. The same driver runs the full-size
assigned archs on a real mesh via repro.launch.train.)
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro import checkpoint
from repro.configs.base import ModelConfig
from repro.data import DataConfig, TokenPipeline
from repro.models import get_model
from repro.train import (AdamWConfig, LossCurveMonitor, TrainConfig,
                         init_train_state, make_train_step)

# ~100M params: 12L × d640 × ff2560, 32k vocab (llama-ish)
GPT_100M = ModelConfig(
    arch="repro-gpt-100m", family="dense",
    n_layers=12, d_model=640, n_heads=10, n_kv_heads=10, d_ff=2560,
    vocab_size=32_000, rope_theta=10000.0, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    model = get_model(GPT_100M)
    n_params = GPT_100M.param_count()
    print(f"[100m] params≈{n_params / 1e6:.1f}M")

    tc = TrainConfig(optimizer=AdamWConfig(
        peak_lr=6e-4, warmup_steps=30, total_steps=args.steps))
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    pipe = TokenPipeline(DataConfig(vocab_size=GPT_100M.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))
    state = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
    last = checkpoint.latest_step(args.ckpt_dir)
    if last is not None:
        state = checkpoint.restore(args.ckpt_dir, last,
                                   jax.eval_shape(lambda: state))
        pipe.restore({"batch_idx": last})
        start = last
        print(f"[100m] resumed from step {last}")

    monitor = LossCurveMonitor(degree=2, decay=0.99)
    t0 = time.time()
    for step in range(start, args.steps):
        state, m = step_fn(state, pipe.next())
        loss = float(m["loss"])
        monitor.observe(step, loss)
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step - start + 1) \
                / (time.time() - t0)
            msg = (f"[100m] step {step:4d} loss={loss:.4f} "
                   f"lr={float(m['lr']):.2e} {tok_s:.0f} tok/s")
            if monitor.ready:
                msg += f" slope={monitor.slope_at(step):+.2e}"
                eta = monitor.eta_to(4.0, step)
                if eta is not None:
                    msg += f" eta(loss4.0)={eta}st"
            print(msg, flush=True)
        if (step + 1) % 100 == 0:
            checkpoint.save(args.ckpt_dir, step + 1, state)
            checkpoint.gc_old(args.ckpt_dir, keep=2)

    print(f"[100m] final loss {loss:.4f} "
          f"({'improved' if loss < 9.0 else 'check data'}; "
          f"uniform-vocab CE would be {jnp.log(32000.0):.2f})")


if __name__ == "__main__":
    main()
