"""One declarative FitSpec, four execution surfaces.

    PYTHONPATH=src python examples/fitspec_surfaces.py

The same spec — robust (Tukey IRLS) cubic fitting under 15% gross
contamination — runs eagerly, over a chunked stream, on a (fake 8-device)
mesh, and through the continuous-batching fit server, and every surface
returns the same coefficients.  Swap one field (method="lse"/"lspia",
degree=DegreeSearch(...), basis="chebyshev", a NumericsPolicy) and all
four surfaces follow: method choice is orthogonal to execution strategy.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import streaming

rng = np.random.default_rng(0)
n = 8192
xs = rng.uniform(-2.0, 2.0, n)
true = np.array([1.0, -0.5, 0.0, 0.3])
ys = np.polyval(true[::-1], xs) + rng.normal(0, 0.05, n)
bad = rng.choice(n, n * 15 // 100, replace=False)
ys[bad] += rng.choice([-1.0, 1.0], bad.size) * 50.0      # gross outliers
x = jnp.asarray(xs, jnp.float32)
y = jnp.asarray(ys, jnp.float32)

spec = api.FitSpec(degree=3, method="irls",
                   irls=api.IRLSOptions(loss="tukey"))
print(f"spec: {spec}\ntrue coeffs: {true}\n")

# 1 — eager/jit (the spec is the jit static arg)
res = api.fit(x, y, spec)
print("eager       :", np.asarray(res.coeffs),
      f"({int(res.iterations)} IRLS sweeps)")

# 2 — streaming: chunk updates reweight against the running fit
state = spec.streaming()
for lo in range(0, n, 1024):
    state = streaming.update(state, x[lo:lo + 1024], y[lo:lo + 1024])
print("streaming   :", np.asarray(api.stream_result(state).coeffs))

# 3 — distributed: one O(m²) collective per IRLS sweep
if len(jax.devices()) >= 8:
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_host_mesh(data=8, model=1)
    out = spec.distributed(mesh)(x, y)
    print("distributed :", np.asarray(out.coeffs))

# 4 — the fit server: per-request spec, compiled once, zero recompiles
from repro.serve import FitServeConfig, FitServeEngine
engine = FitServeEngine(FitServeConfig(degree=3, n_slots=4,
                                       buckets=(2048,)))
engine.warmup()
req = engine.submit(xs.astype(np.float32), ys.astype(np.float32), spec=spec)
engine.run()
print("serve       :", req.coeffs, f"(R={req.r:.4f})")

# plain LSE for contrast: the outliers drag every surface identically
plain = api.fit(x, y, api.FitSpec(degree=3))
print("\nplain LSE    :", np.asarray(plain.coeffs), "<- dragged by outliers")
