"""Quickstart: the paper's algorithm end to end on its own dataset.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import core

# Table I dataset
x = jnp.asarray([39.206, 29.74, 21.31, 12.087, 1.812, 0.001])
y = jnp.asarray([751.912, 567.121, 403.746, 221.738, 18.8418, 1.88672])

print("=== Matricized LSE fit (paper-faithful: Gram + Gaussian elim) ===")
for order in (1, 2, 3):
    poly = core.polyfit(x, y, order)                 # the paper's path
    qr = core.polyfit(x, y, order,                   # MATLAB-polyfit baseline
                  solver="qr_vandermonde")
    rep = core.fit_report(poly, x, y)
    print(f"order {order}: coeffs     = {poly.coeffs}")
    print(f"         polyfit(QR) = {qr.coeffs}")
    print(f"         R = {float(rep.r):.4f}   Σe² = {float(rep.sse):.4f}")

print("\n=== The matricization identity: A == VᵀV, B == Vᵀy ===")
m = core.gram_moments(x, y, 3)
s = core.power_sums(x, 3)
print("Hankel(power sums) == Gram:",
      bool(jnp.allclose(core.hankel_from_power_sums(s, 3), m.gram)))

print("\n=== Beyond-paper hardening: normalized domain + Chebyshev ===")
hard = core.polyfit(x, y, 3, normalize=True)
print("normalized-domain fit, raw coeffs:", hard.monomial_coeffs())
cheb = core.polyfit(x, y, 3, normalize=True, basis=core.CHEBYSHEV)
print("chebyshev-basis Σe²:",
      float(core.fit_report(cheb, x, y).sse))

print("\n=== Pallas kernel path (TPU target; interpret on CPU) ===")
# engine="auto" picks the path from shape/basis/backend (repro.engine);
# force the kernel here so the CPU demo still exercises it
pk = core.polyfit(x, y, 3, engine="kernel")
print("kernel-accumulated coeffs:", pk.coeffs)

print("\n=== Streaming fit: O(1) state over a 1M-point stream ===")
from repro.core import streaming
from repro.data import curve_dataset

xs, ys, true = curve_dataset(1_000_000, degree=2, noise=5.0, seed=0)
state = streaming.StreamState.create(2)
for lo in range(0, xs.shape[0], 65536):
    state = streaming.update(state, xs[lo:lo + 65536], ys[lo:lo + 65536])
fit = streaming.current_fit(state)
print("true coeffs     :", true)
print("streamed coeffs :", fit.coeffs,
      f"(state: {sum(a.size for a in jax.tree.leaves(state))} floats)")
