"""Batched serving with continuous batching on a reduced model.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
sys.path.insert(0, "src")

import jax

from repro import configs
from repro.models import get_model
from repro.serve import EngineConfig, ServeEngine

cfg = configs.get_smoke_config("yi-6b")
model = get_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

engine = ServeEngine(model, params, EngineConfig(n_slots=4, max_len=96))
rng = jax.random.PRNGKey(1)
reqs = []
for i in range(10):
    rng, sub = jax.random.split(rng)
    prompt = [int(t) for t in jax.random.randint(sub, (6 + i,), 3, 250)]
    reqs.append(engine.submit(prompt, max_new_tokens=16,
                              temperature=0.7 if i % 2 else 0.0))

import time
t0 = time.time()
engine.run()
dt = time.time() - t0
total = sum(len(r.out_tokens) for r in reqs)
print(f"served {len(reqs)} requests / {total} tokens in {dt:.1f}s "
      f"({total/dt:.1f} tok/s, {sum(r.done for r in reqs)} finished)")
for r in reqs[:4]:
    print(f"  req {r.uid} (prompt {len(r.tokens)}t, "
          f"T={r.temperature}): {r.out_tokens}")
