"""Single-pass automatic degree selection, offline and streaming.

    PYTHONPATH=src python examples/select_degree.py

A cubic is planted under noise; the selector sees the degree-8 candidate
ladder.  Watch two things:

* ONE moment accumulation carries the whole ladder (the instrumented
  counter proves it) — no refit per candidate degree;
* the raw SSE column keeps falling forever (more parameters always fit
  the noise a little better) while AICc/BIC/CV all reject the overfit
  and land on degree 3.
"""
import numpy as np
import jax.numpy as jnp

from repro import core, engine
from repro.core import streaming

MAX_DEGREE = 8
rng = np.random.default_rng(0)
n = 4096
x = rng.uniform(-1.0, 1.0, n)
true = np.array([1.0, -0.5, 0.3, 0.9])                 # planted cubic
signal = np.polyval(true[::-1], x)
y = signal + (np.std(signal) / 10.0) * rng.normal(0, 1, n)   # SNR 10
xj = jnp.asarray(x, jnp.float32)
yj = jnp.asarray(y, jnp.float32)

print("=== One-pass selection over the degree ladder (folds=5) ===")
engine.reset_moment_counter()
sel = core.select_degree(xj, yj, max_degree=MAX_DEGREE, folds=5)
counter = engine.moment_counter()
print(f"moment-producing calls: {counter['calls']} "
      f"(points touched: {counter['points']})")
s = sel.sweep.scores
print(f"{'deg':>3} {'SSE':>10} {'AICc':>10} {'BIC':>10} {'CV':>10}")
for d in range(MAX_DEGREE + 1):
    mark = "  <- chosen" if d == sel.best_degree else ""
    print(f"{d:>3} {float(s.sse[d]):>10.3f} {float(s.aicc[d]):>10.1f} "
          f"{float(s.bic[d]):>10.1f} {float(s.cv[d]):>10.3f}{mark}")
print(f"chosen: degree {sel.best_degree} by {sel.criterion} "
      f"(SSE alone would pick {int(np.argmin(np.asarray(s.sse)))} — "
      "monotone, always the overfit)")
print("coeffs:", np.asarray(sel.poly.coeffs))

print("\n=== The same, via the fitting front door ===")
poly = core.polyfit(xj, yj, "auto")
print(f"polyfit(x, y, 'auto') -> degree {poly.degree}")

print("\n=== Streaming: the running best degree as data arrives ===")
state = streaming.StreamState.create(MAX_DEGREE, cv_folds=5)
chunk = 128
for i, lo in enumerate(range(0, n, chunk)):
    state = streaming.update(state, xj[lo:lo + chunk], yj[lo:lo + chunk])
    if i % 4 == 3:
        cur = state.current_selection()
        aicc_best = state.current_selection(criterion="aicc").best_degree
        print(f"after {lo + chunk:>5} pts: cv picks {cur.best_degree}, "
              f"aicc picks {aicc_best}, cv scores (deg 2..5): "
              + " ".join(f"{float(cur.sweep.scores.cv[d]):.3f}"
                         for d in range(2, 6)))
final = state.current_selection()
print(f"final streaming selection: degree {final.best_degree} "
      f"(state is O(k·m²) — fold partials + running total, no history)")
