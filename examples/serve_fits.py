"""Continuous-batching fit serving: ragged curve-fit requests, one compiled
ingest per length bucket, zero recompiles across request churn.

    PYTHONPATH=src python examples/serve_fits.py
"""
import sys
sys.path.insert(0, "src")

import time

import jax.numpy as jnp
import numpy as np

from repro import core
from repro.serve import FitServeConfig, FitServeEngine

rng = np.random.default_rng(0)
engine = FitServeEngine(FitServeConfig(
    degree=3, n_slots=8, buckets=(256, 2048), ridge=1e-9))
engine.warmup()   # compile both buckets' ingest + the solve up front

# a ragged trace: noisy cubics between 20 and 5000 points each
reqs = []
for i in range(100):
    n = int(np.exp(rng.uniform(np.log(20), np.log(5000))))
    x = rng.uniform(-2, 2, n).astype(np.float32)
    y = (1.0 + 0.5 * x - 0.8 * x**2 + 0.3 * x**3
         + rng.normal(0, 0.2, n)).astype(np.float32)
    reqs.append(engine.submit(x, y))

t0 = time.perf_counter()
engine.run()
dt = time.perf_counter() - t0

done = sum(r.done for r in reqs)
pts = sum(r.n for r in reqs)
print(f"served {done}/{len(reqs)} fits ({pts} points) in {dt:.2f}s "
      f"-> {done / dt:.0f} fits/s with "
      f"{engine.compiled_executables()} compiled executables")

# every served fit matches a direct polyfit on the same series
worst = 0.0
for r in reqs:
    ref = core.polyfit(jnp.asarray(r.x), jnp.asarray(r.y), 3).coeffs
    worst = max(worst, float(np.max(np.abs(r.coeffs - np.asarray(ref)))))
print(f"max |serve - direct polyfit| coefficient gap: {worst:.2e}")

for r in reqs[:4]:
    print(f"  req {r.uid}: n={r.n:>5} R={r.r:.4f} "
          f"coeffs={np.round(r.coeffs, 3)}")
assert worst < 1e-3

# per-request FitSpec: the solve policy rides with the request — a tighter
# condition cap, a nested lower degree, or a different method each compile
# once (the spec is the jit static arg) and then coexist, zero recompiles
from repro import api

before = engine.compiled_executables()
x, y = reqs[0].x, reqs[0].y
tight = engine.submit(x, y, spec=api.FitSpec(
    degree=3, numerics=api.NumericsPolicy(solver="gauss", fallback="svd",
                                          cond_cap=10.0)))
line = engine.submit(x, y, spec=api.FitSpec(degree=1))
engine.run()
print(f"\nper-request specs (+{engine.compiled_executables() - before} "
      f"one-time compiles):")
print(f"  cond_cap=10 : fallback_used={tight.fallback_used} "
      f"coeffs={np.round(tight.coeffs, 3)}")
print(f"  degree=1    : coeffs={np.round(line.coeffs, 3)} "
      "(nested, from the same degree-3 slot state)")
